"""Deterministic event-driven simulator of the asynchronous parameter server.

This is the *faithful semantics layer* (DESIGN.md §3): P worker threads,
grouped into processes, share parameters through an asynchronous PS.  Updates
propagate whenever "bandwidth is available" (CAP/VAP/CVAP) or at clock
boundaries only (BSP/SSP), subject to the Consistency Controller.  The
network is a seeded deterministic latency model, with optional stragglers.

Faithfully modelled paper semantics:
  * read-my-writes   — a worker's reads always include its own updates
                       (process-cache write-through);
  * FIFO             — per (sender-process, receiver-process) deliveries are
                       order-preserving;
  * CAP clock bound  — a worker at clock c blocks until every update stamped
                       ≤ c - s - 1 from every peer is delivered to it;
  * VAP value bound  — element-wise unsynchronized accumulators stay within
                       max(u, v_thr) via blocking (Fig. 1 semantics);
  * strong VAP       — half-synchronized update magnitude per parameter is
                       gated to max(u, v_thr), giving divergence ≤ 2·max(u,
                       v_thr) independent of P;
  * SSP              — updates leave only during the synchronization phase;
  * ESSP             — eager variant of SSP (arXiv:1410.8043): the clock gate
                       is SSP's, but propagation is eager.  In this collapsed
                       single-heap model, eager *server* push coincides with
                       eager *worker* push, so the essp spec semantics equal
                       CAP's; the kinds differ in the runtime wire mechanism
                       (the shard coalesces deliveries per destination and
                       flushes one frame per peer at each clock boundary);
  * elastic          — elastic consistency (arXiv:2001.05918): the L2 norm of
                       a worker's whole unobserved-update sum stays within
                       max(‖u‖₂, B) via blocking;
  * batching/priority— outgoing updates within a clock may be sent
                       largest-magnitude first (paper §4.2).

Clock convention (matches SSP, Ho et al. 2013): a worker whose clock value is
``c`` is computing its c-th period (0-based) and its updates are stamped
``c``; a worker at clock ``c`` is guaranteed to see every update stamped
``≤ c - s - 1``.  With s = 0 this is BSP.

The simulator is single-threaded, driven by a heap of timestamped events, and
fully deterministic given a seed — which is what lets the tests assert the
paper's bounds exactly.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import controller
from repro.core.policies import Policy
from repro.core.vector_clock import VectorClock

Key = str
UpdateMap = Dict[Key, np.ndarray]


# ---------------------------------------------------------------------------
# Network model
# ---------------------------------------------------------------------------


class NetworkModel:
    """Deterministic per-message latency: base + seeded jitter.

    ``bandwidth`` (bytes/sim-second) adds a serialization term so that large
    rows cost more — enough structure for the scalability benchmark.
    """

    def __init__(self, base_delay: float = 0.05, jitter: float = 0.05,
                 bandwidth: float = float("inf"), seed: int = 0):
        self.base_delay = base_delay
        self.jitter = jitter
        self.bandwidth = bandwidth
        self.seed = seed

    def delay(self, sender: int, receiver: int, nbytes: int, seq: int) -> float:
        h = np.uint64(hash((self.seed, sender, receiver, seq)) & 0xFFFFFFFF)
        u = float(h) / float(0xFFFFFFFF)
        ser = nbytes / self.bandwidth if self.bandwidth != float("inf") else 0.0
        return self.base_delay + self.jitter * u + ser


# ---------------------------------------------------------------------------
# Updates
# ---------------------------------------------------------------------------


@dataclass
class Update:
    uid: int
    worker: int                  # global thread id
    process: int
    ts: int                      # clock timestamp (0-based period index)
    seq: int                     # per-process FIFO sequence number (-1: unsent)
    key: Key
    delta: np.ndarray
    t_created: float
    delivered_to: set = field(default_factory=set)
    delivery_started: bool = False
    t_fully_delivered: Optional[float] = None

    @property
    def nbytes(self) -> int:
        return int(self.delta.nbytes)


@dataclass
class RunStats:
    sim_time: float = 0.0
    n_updates: int = 0
    n_messages: int = 0
    bytes_sent: int = 0
    # runtime VAP ack traffic: messages vs updates acked inside them — the
    # coalescing ratio of the per-(client, shard, flush) ack batching
    n_ack_msgs: int = 0
    n_acked_updates: int = 0
    block_time_clock: float = 0.0
    block_time_value: float = 0.0
    max_observed_staleness: int = 0
    max_unsynced_mag: float = 0.0
    max_update_mag: float = 0.0
    # elastic-consistency accounting: L2 norms of whole unsynced sums / deltas
    max_unsynced_norm: float = 0.0
    max_update_norm: float = 0.0
    max_divergence: float = 0.0
    max_halfsync_mag: float = 0.0
    divergence_trace: List[Tuple[float, float]] = field(default_factory=list)
    clock_times: List[float] = field(default_factory=list)
    violations: List[str] = field(default_factory=list)

    @property
    def throughput(self) -> float:
        """Clocks completed by all workers per simulated second."""
        if not self.clock_times or self.sim_time == 0:
            return 0.0
        return len(self.clock_times) / self.clock_times[-1]


# worker states
_COMPUTING, _APPLYING, _CLOCK_BLOCKED, _VALUE_BLOCKED, _DONE = range(5)


class AsyncPS:
    """The asynchronous parameter server simulator.

    Parameters
    ----------
    n_workers:        total worker threads (paper: a thread is a worker)
    policy:           consistency policy
    init_params:      key -> initial numpy array (the x0 of §3)
    threads_per_process: co-located workers sharing a process cache
    compute_time:     simulated seconds of computation per clock period —
                      a float, or a callable(worker_id) -> float so strong-
                      scaling workloads can make clocks shard-proportional
    straggler:        worker id -> compute-time multiplier
    """

    def __init__(self, n_workers: int, policy: Policy,
                 init_params: UpdateMap,
                 network: Optional[NetworkModel] = None,
                 threads_per_process: int = 1,
                 compute_time: float = 1.0,
                 straggler: Optional[Dict[int, float]] = None,
                 seed: int = 0,
                 prioritize_by_magnitude: bool = True,
                 check_invariants: bool = True):
        if n_workers % threads_per_process:
            raise ValueError("n_workers must divide into processes evenly")
        self.P = n_workers
        self.tpp = threads_per_process
        self.n_proc = n_workers // threads_per_process
        self.policy = policy
        self.network = network or NetworkModel(seed=seed)
        self.compute_time = compute_time
        self.straggler = straggler or {}
        self.prioritize = prioritize_by_magnitude
        self.check = check_invariants
        self._rngs = [np.random.default_rng(seed * 7919 + w) for w in range(n_workers)]

        self.x0 = {k: np.asarray(v, dtype=np.float64) for k, v in init_params.items()}
        # process caches (views): process -> key -> array
        self.views = [dict((k, v.copy()) for k, v in self.x0.items())
                      for _ in range(self.n_proc)]
        # per-thread element-wise unsynchronized accumulators
        self.unsynced = [dict((k, np.zeros_like(v)) for k, v in self.x0.items())
                         for _ in range(n_workers)]
        # strong-VAP half-synchronized magnitude per key (server-side)
        self.halfsync = {k: np.zeros_like(v) for k, v in self.x0.items()}
        # deliveries waiting on the strong gate, per key (FIFO)
        self.delivery_queue: Dict[Key, List[Update]] = defaultdict(list)

        self.thread_clock = VectorClock(n_workers)
        self.process_clock = VectorClock(self.n_proc)

        # FIFO delivery bookkeeping
        self._last_sched: Dict[Tuple[int, int], float] = defaultdict(float)
        self._delivered_prefix = np.zeros((self.n_proc, self.n_proc), dtype=np.int64)
        self._proc_seq = [0] * self.n_proc
        # per sender process: cumulative seq count sealed at the end of each period
        self._clock_end_seq: List[List[int]] = [[] for _ in range(self.n_proc)]
        # per (sender_proc, recv_proc): last delivered seq, to assert FIFO
        self._last_seq_seen = defaultdict(lambda: -1)

        self.updates: List[Update] = []
        self._uid = itertools.count()
        self._evt = itertools.count()
        self.events: List[Tuple[float, int, str, object]] = []
        self.stats = RunStats()
        self.t = 0.0

        self._state = [_COMPUTING] * n_workers
        self._blocked_since = [0.0] * n_workers
        self._pending: List[List[Tuple[Key, np.ndarray]]] = [[] for _ in range(n_workers)]
        self._pending_idx = [0] * n_workers
        self._outbox: List[List[Update]] = [[] for _ in range(n_workers)]
        self._done_clock = 0
        self.update_fn: Optional[Callable] = None
        self.n_clocks = 0

    # ------------------------------------------------------------------ utils
    def proc_of(self, worker: int) -> int:
        return worker // self.tpp

    def _push_event(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self.events, (t, next(self._evt), kind, payload))

    def _unsynced_norm(self, w: int) -> float:
        """L2 norm of worker w's whole unsynchronized accumulator set."""
        sq = sum(float(np.sum(v * v)) for v in self.unsynced[w].values())
        return math.sqrt(max(sq, 0.0))

    def _elastic_norms(self, w: int, key: Key,
                       delta: np.ndarray) -> Tuple[float, float]:
        """(‖unsynced‖₂ before, ‖unsynced‖₂ after applying delta to key)."""
        sq = sum(float(np.sum(v * v)) for v in self.unsynced[w].values())
        cur = self.unsynced[w][key]
        new = cur + delta
        new_sq = sq - float(np.sum(cur * cur)) + float(np.sum(new * new))
        return math.sqrt(max(sq, 0.0)), math.sqrt(max(new_sq, 0.0))

    def _frontier(self, recv_proc: int) -> np.ndarray:
        """For each other process q: the highest period p such that every
        update from q stamped ≤ p has been delivered to recv_proc."""
        res = []
        for q in range(self.n_proc):
            if q == recv_proc:
                continue
            prefix = self._delivered_prefix[q, recv_proc]
            ends = self._clock_end_seq[q]
            f = 0
            while f < len(ends) and ends[f] <= prefix:
                f += 1
            res.append(f - 1)
        return np.asarray(res, dtype=np.int64)

    # ---------------------------------------------------------------- running
    def run(self, update_fn: Callable, n_clocks: int,
            divergence_every: float = 0.0) -> RunStats:
        """Run every worker for ``n_clocks`` periods.

        update_fn(worker_id, clock, view: ViewHandle, rng) -> {key: delta}
        """
        self.update_fn = update_fn
        self.n_clocks = n_clocks
        for w in range(self.P):
            self._schedule_compute(w)
        next_div = divergence_every if divergence_every > 0 else float("inf")

        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            self.t = max(self.t, t)
            if kind == "compute_done":
                self._on_compute_done(payload)
            elif kind == "deliver":
                self._on_deliver(*payload)
            if self.t >= next_div:
                self._record_divergence()
                next_div = self.t + divergence_every
        if not all(s == _DONE for s in self._state):
            blocked = [w for w in range(self.P) if self._state[w] != _DONE]
            raise RuntimeError(f"simulator deadlock: workers {blocked} not done "
                               f"(states {[self._state[w] for w in blocked]})")
        self.stats.sim_time = self.t
        self._record_divergence()
        if self.check:
            self._final_checks()
        return self.stats

    # ------------------------------------------------------------ worker flow
    def _schedule_compute(self, w: int) -> None:
        self._state[w] = _COMPUTING
        mult = self.straggler.get(w, 1.0)
        base = (self.compute_time(w) if callable(self.compute_time)
                else self.compute_time)
        self._push_event(self.t + base * mult, "compute_done", w)

    def _on_compute_done(self, w: int) -> None:
        clock = self.thread_clock.get(w)
        view = ViewHandle(self, w)
        if self.check and self.n_proc > 1:
            fr = self._frontier(self.proc_of(w))
            st = controller.observed_staleness(clock, fr)
            self.stats.max_observed_staleness = max(self.stats.max_observed_staleness, st)
            if self.policy.clock_bounded and st > self.policy.staleness + 1:
                # +1: the first period has nothing to wait for by definition
                self.stats.violations.append(
                    f"staleness violation: worker {w} clock {clock} observed {st}")
        upd = self.update_fn(w, clock, view, self._rngs[w])
        items = list(upd.items())
        if self.prioritize:
            items.sort(key=lambda kv: -float(np.max(np.abs(kv[1]))))
        self._pending[w] = [(k, np.asarray(d, dtype=np.float64)) for k, d in items]
        self._pending_idx[w] = 0
        self._state[w] = _APPLYING
        self._apply_loop(w)

    def _apply_loop(self, w: int) -> None:
        """Apply pending updates; may park the worker on the value gate."""
        while self._pending_idx[w] < len(self._pending[w]):
            key, delta = self._pending[w][self._pending_idx[w]]
            ok, _ = controller.value_gate(self.policy, self.unsynced[w][key], delta)
            if ok and self.policy.norm_bounded:
                acc_n, new_n = self._elastic_norms(w, key, delta)
                ok = controller.elastic_gate(self.policy, acc_n, new_n)
            if not ok:
                if self._state[w] != _VALUE_BLOCKED:
                    self._state[w] = _VALUE_BLOCKED
                    self._blocked_since[w] = self.t
                return
            if self._state[w] == _VALUE_BLOCKED:
                self.stats.block_time_value += self.t - self._blocked_since[w]
                self._state[w] = _APPLYING
            self._apply_update(w, key, delta)
            self._pending_idx[w] += 1
        self._on_clock(w)

    def _apply_update(self, w: int, key: Key, delta: np.ndarray) -> None:
        pr = self.proc_of(w)
        ts = self.thread_clock.get(w)        # stamped with the current period
        u = Update(uid=next(self._uid), worker=w, process=pr, ts=ts,
                   seq=-1, key=key, delta=delta.copy(), t_created=self.t)
        self.updates.append(u)
        self.stats.n_updates += 1
        m = float(np.max(np.abs(delta))) if delta.size else 0.0
        self.stats.max_update_mag = max(self.stats.max_update_mag, m)
        # read-my-writes: own process cache sees it immediately
        self.views[pr][key] = self.views[pr][key] + delta
        self.unsynced[w][key] = self.unsynced[w][key] + delta
        dn = float(np.linalg.norm(delta)) if delta.size else 0.0
        self.stats.max_update_norm = max(self.stats.max_update_norm, dn)
        if self.check:
            bound = controller.vap_unsynced_bound(self.policy, self.stats.max_update_mag)
            mx = float(np.max(np.abs(self.unsynced[w][key])))
            self.stats.max_unsynced_mag = max(self.stats.max_unsynced_mag, mx)
            if self.policy.value_bounded and mx > bound + 1e-12:
                self.stats.violations.append(
                    f"VAP violation: worker {w} unsynced {mx} > {bound}")
            un = self._unsynced_norm(w)
            self.stats.max_unsynced_norm = max(self.stats.max_unsynced_norm, un)
            if self.policy.norm_bounded:
                nb = controller.elastic_unsynced_bound(
                    self.policy, self.stats.max_update_norm)
                if un > nb + 1e-9:
                    self.stats.violations.append(
                        f"elastic violation: worker {w} "
                        f"unsynced norm {un} > {nb}")
        if self.n_proc == 1:
            u.delivery_started = True
            u.t_fully_delivered = self.t
            self.unsynced[w][key] = self.unsynced[w][key] - u.delta
            return
        if self.policy.push_at_clock_only:
            self._outbox[w].append(u)
        else:
            self._try_start_delivery(u)

    def _try_start_delivery(self, u: Update) -> None:
        """Start propagation, subject to the strong-VAP half-sync gate."""
        if self.delivery_queue[u.key] or not controller.strong_delivery_gate(
                self.policy, self.halfsync[u.key], u.delta):
            self.delivery_queue[u.key].append(u)
            return
        self._start_delivery(u)

    def _start_delivery(self, u: Update) -> None:
        u.delivery_started = True
        u.seq = self._proc_seq[u.process]
        self._proc_seq[u.process] += 1
        self.halfsync[u.key] = self.halfsync[u.key] + np.abs(u.delta)
        if self.check:
            mx = float(np.max(self.halfsync[u.key]))
            self.stats.max_halfsync_mag = max(self.stats.max_halfsync_mag, mx)
        pr = u.process
        for q in range(self.n_proc):
            if q == pr:
                continue
            d = self.network.delay(pr, q, u.nbytes, u.seq)
            t_del = max(self.t + d, self._last_sched[(pr, q)] + 1e-9)  # FIFO
            self._last_sched[(pr, q)] = t_del
            self._push_event(t_del, "deliver", (u.uid, q))
            self.stats.n_messages += 1
            self.stats.bytes_sent += u.nbytes

    def _on_deliver(self, uid: int, q: int) -> None:
        u = self.updates[uid]
        if self.check:
            last = self._last_seq_seen[(u.process, q)]
            if u.seq <= last:
                self.stats.violations.append(
                    f"FIFO violation: proc {u.process}->{q} seq {u.seq} after {last}")
            self._last_seq_seen[(u.process, q)] = u.seq
        u.delivered_to.add(q)
        self.views[q][u.key] = self.views[q][u.key] + u.delta
        self._delivered_prefix[u.process, q] += 1
        if len(u.delivered_to) == self.n_proc - 1:
            u.t_fully_delivered = self.t
            # exact subtraction: the accumulators received exactly u.delta /
            # |u.delta| when the update started, so the inverse is exact —
            # snapping sub-1e-12 residuals to zero here could discard other
            # legitimately in-flight tiny deltas sharing the accumulator
            # (the value/strong gates keep their own > 1e-12 dead zone, so
            # residue from mixed orderings never wedges a worker).  Keeps
            # the spec in lockstep with the runtime's VAP accounting.
            self.unsynced[u.worker][u.key] = \
                self.unsynced[u.worker][u.key] - u.delta
            self.halfsync[u.key] = self.halfsync[u.key] - np.abs(u.delta)
            # half-sync budget freed: release queued deliveries for this key
            dq = self.delivery_queue.get(u.key)
            while dq:
                nxt = dq[0]
                if controller.strong_delivery_gate(self.policy, self.halfsync[nxt.key], nxt.delta):
                    dq.pop(0)
                    self._start_delivery(nxt)
                else:
                    break
            self._wake_value_blocked()
        self._wake_clock_blocked()

    def _wake_value_blocked(self) -> None:
        for w in range(self.P):
            if self._state[w] == _VALUE_BLOCKED:
                self._apply_loop(w)

    def _wake_clock_blocked(self) -> None:
        for w in range(self.P):
            if self._state[w] == _CLOCK_BLOCKED:
                self._check_clock_gate(w)

    # ---------------------------------------------------------------- clocks
    def _on_clock(self, w: int) -> None:
        """Worker finished applying its updates for this period: Clock()."""
        pr = self.proc_of(w)
        # SSP/BSP: this thread's updates leave during its synchronization phase
        for u in self._outbox[w]:
            self._try_start_delivery(u)
        self._outbox[w] = []
        new_clock = self.thread_clock.tick(w)
        # process clock = min of its threads (paper §4.2)
        lo = min(self.thread_clock.get(t)
                 for t in range(pr * self.tpp, (pr + 1) * self.tpp))
        while self.process_clock.get(pr) < lo:
            # the process completed a period: seal its cumulative seq count
            self._clock_end_seq[pr].append(self._proc_seq[pr])
            self.process_clock.set(pr, self.process_clock.get(pr) + 1)
        self._wake_clock_blocked()
        if min(self.thread_clock.get(t) for t in range(self.P)) > self._done_clock:
            self._done_clock += 1
            self.stats.clock_times.append(self.t)
        if new_clock >= self.n_clocks:
            self._state[w] = _DONE
            return
        self._check_clock_gate(w, first=True)

    def _check_clock_gate(self, w: int, first: bool = False) -> None:
        if self.n_proc == 1:
            self._schedule_compute(w)
            return
        fr = self._frontier(self.proc_of(w))
        if controller.clock_gate(self.policy, self.thread_clock.get(w), fr):
            if self._state[w] == _CLOCK_BLOCKED:
                self.stats.block_time_clock += self.t - self._blocked_since[w]
            self._schedule_compute(w)
        else:
            if first or self._state[w] != _CLOCK_BLOCKED:
                self._blocked_since[w] = self.t
            self._state[w] = _CLOCK_BLOCKED

    # ------------------------------------------------------------- reporting
    def _record_divergence(self) -> None:
        if self.n_proc < 2:
            return
        worst = 0.0
        for k in self.x0:
            stack = np.stack([v[k] for v in self.views])
            worst = max(worst, float(np.max(stack.max(0) - stack.min(0))))
        self.stats.max_divergence = max(self.stats.max_divergence, worst)
        self.stats.divergence_trace.append((self.t, worst))

    def _final_checks(self) -> None:
        # eventual consistency: once everything is delivered all views agree
        totals = {k: v.copy() for k, v in self.x0.items()}
        for u in self.updates:
            totals[u.key] = totals[u.key] + u.delta
        for k in self.x0:
            for q in range(self.n_proc):
                if not np.allclose(self.views[q][k], totals[k], atol=1e-6):
                    self.stats.violations.append(
                        f"eventual-consistency violation on {k} (process {q})")

    def master_value(self, key: Key) -> np.ndarray:
        total = self.x0[key].copy()
        for u in self.updates:
            if u.key == key:
                total = total + u.delta
        return total


class ViewHandle:
    """Read API handed to update_fn — a Get() through the cache hierarchy."""

    def __init__(self, ps: AsyncPS, worker: int):
        self._ps = ps
        self._worker = worker
        self.worker = worker
        self.gets = 0

    def get(self, key: Key) -> np.ndarray:
        self.gets += 1
        return self._ps.views[self._ps.proc_of(self._worker)][key].copy()

    def keys(self) -> Sequence[Key]:
        return list(self._ps.x0.keys())
