"""TPU/SPMD adaptation of the consistency models (DESIGN.md §3, layer 2).

On a lockstep SPMD mesh, each data-parallel replica keeps its own *drifting*
copy of the parameters plus an accumulated unsynchronized delta ``δ``.
Updates apply locally first (read-my-writes); a jax.lax Consistency
Controller decides per step whether the delta all-reduce runs:

    BSP   : every step.
    SSP/CAP/ESSP(s): every s-th step (staleness ≤ s by construction; in
            lockstep SPMD the push-early vs push-at-clock distinction AND
            ESSP's eager server push both collapse — every sync epoch is a
            full exchange, so the server can't be "ahead" of it; see
            DESIGN.md §3 and arXiv:1410.8043).
    VAP(v): when any replica's ‖δ‖∞ would exceed v_thr — one scalar pmax per
            step, the TPU analogue of the paper's per-worker blocking.
    CVAP  : clock OR value trigger.
    elastic(B): when any replica's whole-tree ‖δ‖₂ would exceed B — the
            elastic-consistency bound (arXiv:2001.05918) as a single scalar
            pmax trigger, so post-step ‖δ‖₂ ≤ max(‖u‖₂, B) by construction.

The sync itself is ``params ← params + (Σ_replicas δ) − δ`` — the associative
and commutative update rule of §2, so FIFO/ordering concerns vanish and the
result equals the paper's "all updates visible" state.

Beyond-paper options (EXPERIMENTS.md §Perf):
  * ``compress="bf16"``   — deltas all-reduce in bf16 with fp32 error-feedback
    residual (the VAP bound caps |δ| and hence the quantization error).
  * ``hierarchy=k``       — two-level sync: every trigger syncs within the
    pod ('data' axis); only every k-th sync crosses pods ('pod' axis),
    exploiting the ICI≫DCI bandwidth gap.  Cross-pod contributions accumulate
    in a separate ``pod_pending`` buffer (replicated within a pod) so nothing
    is double-counted.  Effective staleness: s intra-pod, k·s cross-pod.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.policies import INF, Policy

PyTree = Any


# ---------------------------------------------------------------------------
# Sync state (a pytree carried in TrainState)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SyncState:
    delta: PyTree                  # accumulated unsynchronized updates
    residual: PyTree               # error-feedback residual (compress mode)
    pod_pending: PyTree            # intra-pod aggregates not yet crossed pods
    steps_since_sync: jnp.ndarray  # i32 scalar
    sync_count: jnp.ndarray        # i32 scalar — total sync epochs so far
    max_update_mag: jnp.ndarray    # f32 scalar — running max ‖u‖∞ (bound check)
    max_update_l2: jnp.ndarray     # f32 scalar — running max ‖u‖₂ (elastic)


def init_sync_state(params: PyTree, hierarchy: int = 0,
                    compress: Optional[str] = None,
                    dtype=None) -> SyncState:
    """dtype: storage dtype of the delta accumulator (bf16 halves both the
    resident bytes and the sync all-reduce volume; the VAP bound caps |δ|,
    so bf16's relative precision is adequate)."""
    zeros = lambda: jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), params)
    none_tree = jax.tree.map(lambda x: jnp.zeros((), x.dtype), params)
    return SyncState(
        delta=zeros(),
        residual=zeros() if compress else none_tree,
        pod_pending=zeros() if hierarchy and hierarchy > 1 else none_tree,
        steps_since_sync=jnp.zeros((), jnp.int32),
        sync_count=jnp.zeros((), jnp.int32),
        max_update_mag=jnp.zeros((), jnp.float32),
        max_update_l2=jnp.zeros((), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_zeros(t: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, t)


def tree_max_abs(t: PyTree) -> jnp.ndarray:
    """max over all leaves of ‖leaf‖∞ (f32 scalar)."""
    leaves = [jnp.max(jnp.abs(x)).astype(jnp.float32) for x in jax.tree.leaves(t)]
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros((), jnp.float32)


def tree_l2_norm(t: PyTree) -> jnp.ndarray:
    """L2 norm over the whole tree, ‖t‖₂ (f32 scalar) — the elastic bound's
    aggregate, matching the simulator's whole-accumulator norm."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(t)]
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _psum_tree(t: PyTree, axes: Sequence[str], compress: Optional[str]) -> PyTree:
    axes = tuple(axes)
    if compress == "bf16":
        return jax.tree.map(
            lambda x: lax.psum(x.astype(jnp.bfloat16), axes).astype(x.dtype), t)
    return jax.tree.map(lambda x: lax.psum(x, axes), t)


# ---------------------------------------------------------------------------
# Triggers — the jax.lax Consistency Controller
# ---------------------------------------------------------------------------


def sync_trigger(policy: Policy, sync_state: SyncState, new_delta: PyTree,
                 dp_axes: Sequence[str],
                 trigger_axes: Optional[Sequence[str]] = None) -> jnp.ndarray:
    """Mesh-uniform boolean: must this step run the delta all-reduce?

    The value trigger is made uniform with a pmax over ``trigger_axes`` —
    the data-parallel axes PLUS the model axis when parameters are
    tensor-sharded (each model shard only sees its slice's ‖δ‖∞; all shards
    must take the same cond branch).  The paper's per-worker block becomes a
    mesh-wide sync epoch — conservative, so the VAP invariant still holds
    (DESIGN.md §3).
    """
    axes = tuple(trigger_axes) if trigger_axes is not None else tuple(dp_axes)
    trig = jnp.zeros((), jnp.bool_)
    if policy.clock_bounded:
        s = max(policy.staleness, 0)
        trig = trig | (sync_state.steps_since_sync + 1 >= s + 1)
    if policy.value_bounded and policy.value_bound != INF:
        local = tree_max_abs(new_delta)
        glob = lax.pmax(local, axes) if axes else local
        trig = trig | (glob > policy.value_bound)
    if policy.norm_bounded:
        # elastic: one scalar — would any replica's whole-tree ‖δ‖₂ exceed
        # the bound?  Same conservative mesh-wide uniformity as VAP.
        local = tree_l2_norm(new_delta)
        glob = lax.pmax(local, axes) if axes else local
        trig = trig | (glob > policy.value_bound)
    if not (policy.clock_bounded or policy.value_bounded
            or policy.norm_bounded):
        trig = jnp.ones((), jnp.bool_)     # degenerate: stay synchronous
    return trig


# ---------------------------------------------------------------------------
# The sync step
# ---------------------------------------------------------------------------


def apply_and_sync(
    params: PyTree,
    sync_state: SyncState,
    update: PyTree,
    policy: Policy,
    dp_axes: Sequence[str],
    compress: Optional[str] = None,
    hierarchy: int = 0,
    pod_axis: Optional[str] = None,
    trigger_axes: Optional[Sequence[str]] = None,
) -> Tuple[PyTree, SyncState, jnp.ndarray]:
    """Apply a local optimizer update, then maybe synchronize replicas.

    Returns (params, sync_state, synced: bool scalar).

    * read-my-writes: ``params`` immediately include ``update``.
    * on sync: params ← params + (psum(δ) − δ); δ ← 0.  Because updates are
      additive and commutative this equals the fully-synchronized state.
    """
    dp_axes = tuple(dp_axes)
    params = tree_add(params, update)
    # keep the accumulator's storage dtype (bf16 under state_dtype=bfloat16)
    new_delta = jax.tree.map(lambda d, u: (d + u).astype(d.dtype),
                             sync_state.delta, update)
    umag = jnp.maximum(sync_state.max_update_mag, tree_max_abs(update))
    ul2 = jnp.maximum(sync_state.max_update_l2, tree_l2_norm(update))
    trig = sync_trigger(policy, sync_state, new_delta, dp_axes,
                        trigger_axes=trigger_axes)

    hierarchical = bool(hierarchy and hierarchy > 1 and pod_axis
                        and pod_axis in dp_axes)

    if not dp_axes:
        # single replica: every "sync" is a no-op but the clock still ticks
        new_state = SyncState(
            delta=jax.tree.map(lambda d: jnp.where(trig, jnp.zeros_like(d), d), new_delta),
            residual=sync_state.residual,
            pod_pending=sync_state.pod_pending,
            steps_since_sync=jnp.where(trig, 0, sync_state.steps_since_sync + 1).astype(jnp.int32),
            sync_count=(sync_state.sync_count + trig.astype(jnp.int32)),
            max_update_mag=umag,
            max_update_l2=ul2,
        )
        return params, new_state, trig

    def compressed_send(d, r):
        """Quantize δ+r to bf16, keep the error as the next residual."""
        send = tree_add(d, r)
        comp = jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(x.dtype), send)
        return comp, tree_sub(send, comp)

    def do_sync(operand):
        p, d, r, pend, cnt = operand
        if hierarchical:
            intra = tuple(a for a in dp_axes if a != pod_axis)
            if compress:
                d_send, r = compressed_send(d, r)
            else:
                d_send = d
            tot_intra = _psum_tree(d_send, intra, compress)
            p = tree_add(p, tree_sub(tot_intra, d_send))
            pend = tree_add(pend, tot_intra)
            cross = (cnt % hierarchy) == (hierarchy - 1)

            def do_cross(p, pend):
                tot = _psum_tree(pend, (pod_axis,), compress)
                return tree_add(p, tree_sub(tot, pend)), tree_zeros(pend)

            p, pend = lax.cond(cross, do_cross, lambda p, pend: (p, pend), p, pend)
            return p, tree_zeros(d), r, pend

        if compress:
            d_send, r = compressed_send(d, r)
        else:
            d_send = d
        tot = _psum_tree(d_send, dp_axes, compress)
        p = tree_add(p, tree_sub(tot, d_send))
        return p, tree_zeros(d), r, pend

    def no_sync(operand):
        p, d, r, pend, _ = operand
        return p, d, r, pend

    params, delta_out, residual, pod_pending = lax.cond(
        trig, do_sync, no_sync,
        (params, new_delta, sync_state.residual, sync_state.pod_pending,
         sync_state.sync_count))

    new_state = SyncState(
        delta=delta_out,
        residual=residual,
        pod_pending=pod_pending,
        steps_since_sync=jnp.where(trig, 0, sync_state.steps_since_sync + 1).astype(jnp.int32),
        sync_count=sync_state.sync_count + trig.astype(jnp.int32),
        max_update_mag=umag,
        max_update_l2=ul2,
    )
    return params, new_state, trig


def force_sync(params: PyTree, sync_state: SyncState,
               dp_axes: Sequence[str]) -> Tuple[PyTree, SyncState]:
    """Unconditional sync (used at checkpoint/eval boundaries)."""
    dp_axes = tuple(dp_axes)
    if dp_axes:
        tot = _psum_tree(sync_state.delta, dp_axes, None)
        params = tree_add(params, tree_sub(tot, sync_state.delta))
    new_state = dataclasses.replace(
        sync_state,
        delta=tree_zeros(sync_state.delta),
        steps_since_sync=jnp.zeros((), jnp.int32),
        sync_count=sync_state.sync_count + 1,
    )
    return params, new_state


def vap_invariant_ok(policy: Policy, sync_state: SyncState) -> jnp.ndarray:
    """‖δ‖∞ ≤ max(u_max, v_thr) — checked by tests after every step."""
    if not policy.value_bounded:
        return jnp.ones((), jnp.bool_)
    bound = jnp.maximum(sync_state.max_update_mag, policy.value_bound)
    return tree_max_abs(sync_state.delta) <= bound + 1e-6


def elastic_invariant_ok(policy: Policy, sync_state: SyncState) -> jnp.ndarray:
    """‖δ‖₂ ≤ max(‖u‖₂_max, B) — checked by tests after every step."""
    if not policy.norm_bounded:
        return jnp.ones((), jnp.bool_)
    bound = jnp.maximum(sync_state.max_update_l2, policy.value_bound)
    return tree_l2_norm(sync_state.delta) <= bound + 1e-6
