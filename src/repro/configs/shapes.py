"""The four assigned input shapes (see the assignment brief)."""
from __future__ import annotations

from repro.configs.base import InputShape

INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", seq_len=4_096, global_batch=256, mode="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32_768, global_batch=32, mode="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32_768, global_batch=128, mode="decode"),
    "long_500k": InputShape("long_500k", seq_len=524_288, global_batch=1, mode="decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown input shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]
