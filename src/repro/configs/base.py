"""Configuration dataclasses for the model zoo and the distributed runtime.

Every assigned architecture is expressed as a :class:`ModelConfig`.  Configs
are plain frozen dataclasses so they hash, print, and diff cleanly; the
registry in :mod:`repro.configs.registry` maps ``--arch`` ids onto them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts feed-forward configuration."""

    n_experts: int
    top_k: int
    d_expert: int                     # hidden width of each routed expert
    n_shared_experts: int = 0         # DeepSeek-style always-on experts
    d_shared: int = 0                 # hidden width of the shared expert block
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01     # load-balance loss coefficient
    first_dense_layers: int = 0       # leading layers that use a dense FFN
    d_ff_dense: int = 0               # hidden width of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RecurrentConfig:
    """Recurrent mixer configuration (RG-LRU or Mamba-2 SSD)."""

    kind: str = "rglru"               # "rglru" | "mamba2"
    width: int = 0                    # recurrence width (d_inner)
    conv_width: int = 4
    # mamba2-only:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    # rglru-only:
    block_width: int = 0              # rglru gate block-diagonal width (0 = dense)


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend (VLM patches / audio conditioning).

    Per the brief, the conv/ViT encoder itself is NOT implemented; the
    frontend contributes precomputed embeddings via ``input_specs``.
    """

    kind: str                         # "vision" | "audio"
    n_embeds: int                     # patches (vision) / conditioning frames (audio)
    embed_dim: int                    # dimension of provided embeddings


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense|moe|vlm|audio|hybrid|ssm
    source: str                       # citation for the assignment table
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention flavour ---------------------------------------------------
    attn_kind: str = "full"           # "full" | "swa" | "alternating" (local/global)
    # int8-compress the sequence-parallel all-gathers (lossy ~0.4% activation
    # error; halves the dominant collective volume — EXPERIMENTS §Perf pair 2)
    compress_gathers: bool = False
    window: int = 4096                # sliding-window size where applicable
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0

    # --- block flavour -------------------------------------------------------
    norm_kind: str = "rmsnorm"        # "rmsnorm" | "gemma_rmsnorm" | "layernorm" | "nonparam_ln"
    post_norm: bool = False           # gemma2-style post-sublayer norms
    act: str = "silu"                 # "silu" | "gelu"
    gated_mlp: bool = True            # SwiGLU/GeGLU vs plain MLP
    tie_embeddings: bool = False
    layer_pattern: Tuple[str, ...] = ("attn",)   # cycled over layers

    # --- optional subsystems -------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    frontend: Optional[FrontendConfig] = None

    # --- distribution --------------------------------------------------------
    tp_strategy: str = "head"         # "head" | "seq" | "replicated"  (see DESIGN §6)
    # long-context mode: attention archs fall back to sliding-window caches so
    # that the 500k decode shape has bounded memory (DESIGN §6).
    long_context_window: int = 4096

    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"           # activation/param compute dtype
    param_dtype: str = "float32"      # master/optimizer dtype

    # -------------------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # Padded vocab so the output head shards evenly over the model axis.
    def padded_vocab(self, tp: int) -> int:
        v = self.vocab_size
        return ((v + tp - 1) // tp) * tp

    @property
    def has_attention(self) -> bool:
        return "attn" in self.layer_pattern

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind list of length n_layers."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * self.n_heads * qd                       # q proj
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)   # kv down
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d             # out
                else:
                    total += d * self.n_heads * self.d_head              # q
                    total += 2 * d * self.n_kv_heads * self.d_head       # k,v
                    total += self.n_heads * self.d_head * d              # out
            elif kind == "rec":
                r = self.recurrent
                if r.kind == "rglru":
                    w = r.width
                    total += 2 * d * w            # in projections (x, gate)
                    total += w * d                # out projection
                    total += r.conv_width * w     # causal conv
                    total += 3 * w                # lru gates/params (approx)
                else:  # mamba2
                    w = r.width
                    nh = w // r.head_dim
                    total += d * (2 * w + 2 * r.n_groups * r.d_state + nh)
                    total += r.conv_width * (w + 2 * r.n_groups * r.d_state)
                    total += w * d
                    total += 2 * nh
            if kind in ("attn", "rec"):
                total += self._ffn_params_for_layer()
        return total

    def _ffn_params_for_layer(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            p = m.n_experts * (3 if self.gated_mlp else 2) * d * m.d_expert
            p += d * m.n_experts                                         # router
            if m.n_shared_experts:
                p += (3 if self.gated_mlp else 2) * d * m.d_shared
            return p
        mult = 3 if self.gated_mlp else 2
        return mult * d * self.d_ff

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k only) — for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        # subtract inactive routed experts
        per_expert = (3 if self.gated_mlp else 2) * d * m.d_expert
        n_moe_layers = sum(
            1 for i, k in enumerate(self.layer_kinds())
            if k == "attn" and i >= m.first_dense_layers
        )
        total -= n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                         # "train" | "prefill" | "decode"


# ---------------------------------------------------------------------------
# Training / runtime config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConsistencySpec:
    """User-facing consistency selection; mirrors the paper's policies."""

    model: str = "bsp"                # bsp|ssp|cap|essp|vap|cvap|elastic
    staleness: int = 0                # s  (ssp/cap/essp/cvap)
    value_bound: float = 0.0          # v_thr (vap/cvap) / norm B (elastic)
    strong: bool = False              # strong VAP variant (simulator only)


@dataclass(frozen=True)
class TrainConfig:
    arch: str = "olmo-1b"
    shape: str = "train_4k"
    steps: int = 100
    lr: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.0
    optimizer: str = "adam"           # "sgd" | "momentum" | "adam"
    seed: int = 0
    consistency: ConsistencySpec = field(default_factory=ConsistencySpec)
    remat: bool = True
    microbatch: int = 0               # 0 = no microbatching
    log_every: int = 10
    checkpoint_dir: str = ""
    checkpoint_every: int = 0
    # beyond-paper options (see EXPERIMENTS.md §Perf)
    quantize_sync: bool = False       # bf16 delta all-reduce (error feedback)
    hierarchical_sync: int = 0        # sync across pods every k-th sync
    state_dtype: str = "float32"      # delta + Adam moments storage dtype epoch


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
