"""RecurrentGemma-9B [arXiv:2402.19427] — RG-LRU + local attention, 1:2 pattern."""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,              # MQA
    d_head=256,
    d_ff=12288,
    vocab_size=256000,
    attn_kind="swa",           # all attention layers are local, window 2048
    window=2048,
    norm_kind="gemma_rmsnorm",
    act="gelu",
    gated_mlp=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    layer_pattern=("rec", "rec", "attn"),
    recurrent=RecurrentConfig(kind="rglru", width=4096, conv_width=4),
    tp_strategy="head",
)
