from repro.configs.base import (ConsistencySpec, FrontendConfig, InputShape,
                                MLAConfig, ModelConfig, MoEConfig,
                                RecurrentConfig, TrainConfig)
from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.configs.shapes import INPUT_SHAPES, get_shape

__all__ = [
    "ARCHS", "ConsistencySpec", "FrontendConfig", "INPUT_SHAPES", "InputShape",
    "MLAConfig", "ModelConfig", "MoEConfig", "RecurrentConfig", "TrainConfig",
    "get_config", "get_shape", "reduced_config",
]
