"""Gemma2-2B [arXiv:2408.00118] — alternating local/global attention, softcaps.

8 heads < tp=16, so this config uses the seq-TP attention strategy
(DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    source="arXiv:2408.00118",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab_size=256000,
    attn_kind="alternating",
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm_kind="gemma_rmsnorm",
    post_norm=True,
    act="gelu",
    gated_mlp=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    tp_strategy="seq",
)
