"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434] — MLA + 64-routed/2-shared MoE.

Assignment note: the header says "MoE 64e top-6" while the bracket note says
"160 routed" — 160 is full DeepSeek-V2; V2-LITE has 64 routed experts, which
matches the header and is what we implement (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,             # MLA: all heads share the compressed kv latent
    d_head=192,                # qk head dim = nope(128) + rope(64)
    d_ff=1408,                 # routed-expert hidden width
    vocab_size=102400,
    norm_kind="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408,
                  n_shared_experts=2, d_shared=2816,
                  first_dense_layers=1, d_ff_dense=10944),
    tp_strategy="head",
)
