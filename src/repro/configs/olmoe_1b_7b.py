"""OLMoE-1B-7B [arXiv:2409.02060] — 64-expert top-8 MoE, qk-norm."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    source="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,                 # per-expert hidden width
    vocab_size=50304,
    qk_norm=True,
    norm_kind="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
    tp_strategy="head",
)
