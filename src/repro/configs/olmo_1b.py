"""OLMo-1B [arXiv:2402.00838] — dense, non-parametric LayerNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    source="arXiv:2402.00838",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=8192,
    vocab_size=50304,
    norm_kind="nonparam_ln",   # OLMo uses LayerNorm without scale/bias
    act="silu",
    gated_mlp=True,
    rope_theta=10_000.0,
    tie_embeddings=True,       # OLMo-1B ties input/output embeddings
    tp_strategy="head",
)
