"""MusicGen-medium [arXiv:2306.05284] — decoder-only LM over EnCodec tokens.

The EnCodec tokenizer and the T5 text encoder are STUBBED per the brief:
``input_specs`` supplies audio-token ids (vocab 2048) plus precomputed
conditioning embeddings consumed as a prefix (cross-attention replaced by
prefix conditioning — DESIGN.md §5).  24 heads are not divisible by tp=16,
so this config uses the seq-TP strategy.
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab_size=2048,
    norm_kind="layernorm",
    act="gelu",
    gated_mlp=False,           # MusicGen uses a plain (non-gated) MLP
    rope_theta=10_000.0,       # deviation: sinusoidal absolute -> RoPE (DESIGN §5)
    frontend=FrontendConfig(kind="audio", n_embeds=64, embed_dim=1536),
    tp_strategy="seq",
)
