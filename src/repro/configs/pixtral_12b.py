"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — Mistral-Nemo decoder backbone.

The Pixtral-ViT vision encoder is STUBBED per the brief: ``input_specs``
supplies precomputed patch embeddings that are merged into the token stream
at masked positions (see models/model.py).
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    norm_kind="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision", n_embeds=256, embed_dim=5120),
    tp_strategy="head",
)
