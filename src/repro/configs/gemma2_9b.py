"""Gemma2-9B [arXiv:2408.00118] — alternating local/global attention, softcaps."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    source="arXiv:2408.00118",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=14336,
    vocab_size=256000,
    attn_kind="alternating",   # local (sliding window) / global, interleaved
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm_kind="gemma_rmsnorm",
    post_norm=True,
    act="gelu",
    gated_mlp=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    tp_strategy="head",
)
