"""Mamba2-130M [arXiv:2405.21060] — attention-free SSD (state-space duality).

vocab 50280 is padded to 50304 for even tp=16 sharding (logits masked).
The model is tiny (130M), so mixer weights are replicated and only the
vocab-sharded embedding/logits use the model axis (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    n_layers=24,
    d_model=768,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_head=0,
    d_ff=0,                    # no FFN — the mamba block is the whole layer
    vocab_size=50280,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    layer_pattern=("rec",),
    recurrent=RecurrentConfig(kind="mamba2", width=1536, conv_width=4,
                              d_state=128, head_dim=64, n_groups=1,
                              chunk_size=256),
    tp_strategy="replicated",
)
