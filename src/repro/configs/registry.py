"""Architecture registry: ``--arch <id>`` → ModelConfig.

Besides the 10 full assigned configs, every architecture exposes a REDUCED
smoke variant (≤2 layers, d_model ≤ 512, ≤4 experts) used by the per-arch CPU
smoke tests; the full configs are only ever lowered via the dry-run
(ShapeDtypeStruct — no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (FrontendConfig, MLAConfig, ModelConfig,
                                MoEConfig, RecurrentConfig)

from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.gemma2_9b import CONFIG as _gemma2_9b
from repro.configs.gemma2_2b import CONFIG as _gemma2_2b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.mamba2_130m import CONFIG as _mamba2

ARCHS = {
    c.name: c
    for c in [
        _olmoe, _olmo, _pixtral, _qwen3, _gemma2_9b, _gemma2_2b,
        _recurrentgemma, _musicgen, _deepseek, _mamba2,
    ]
}

# ---------------------------------------------------------------------------
# Beyond-paper performance variants (EXPERIMENTS.md §Perf) — NOT part of the
# assigned 10; selectable for A/B dry-runs.
# ---------------------------------------------------------------------------
ARCHS["mamba2-130m-sp"] = dataclasses.replace(
    _mamba2, name="mamba2-130m-sp", tp_strategy="seq_ssm")
ARCHS["pixtral-12b-cg"] = dataclasses.replace(
    _pixtral, name="pixtral-12b-cg", compress_gathers=True)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (≤2 layers, d≤512, ≤4e)."""
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke",
        vocab_size=512,
        d_model=128,
        window=32,
        long_context_window=32,
        tp_strategy=cfg.tp_strategy,
    )
    if cfg.has_attention:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) or 4, d_head=32)
    if cfg.layer_pattern == ("attn",):
        kw["n_layers"] = 2
    else:
        kw["n_layers"] = len(cfg.layer_pattern)      # one full pattern period
    if cfg.d_ff:
        kw["d_ff"] = 256
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_expert=64,
            d_shared=64 if cfg.moe.n_shared_experts else 0,
            d_ff_dense=128 if cfg.moe.first_dense_layers else 0,
        )
        if cfg.moe.first_dense_layers:
            kw["n_layers"] = 2                       # 1 dense + 1 moe layer
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=32,
                              qk_rope_head_dim=16, v_head_dim=32)
        kw["d_head"] = 48
    if cfg.recurrent is not None:
        if cfg.recurrent.kind == "rglru":
            kw["recurrent"] = dataclasses.replace(cfg.recurrent, width=128)
        else:
            kw["recurrent"] = dataclasses.replace(
                cfg.recurrent, width=128, head_dim=32, d_state=16, chunk_size=16)
    if cfg.frontend is not None:
        kw["frontend"] = FrontendConfig(kind=cfg.frontend.kind, n_embeds=8,
                                        embed_dim=128)
    return dataclasses.replace(cfg, **kw)
