"""Pytree checkpointing to npz (+ json metadata).

Checkpoints are taken at sync boundaries: the trainer calls
``core.sync.force_sync`` first, so the saved parameters are the
fully-synchronized state (every worker's updates visible — the paper's
"true" sequence x_t), making checkpoints consistency-model independent.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    arrays = _flatten_with_paths(tree)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    meta = {"step": step, "n_arrays": len(arrays), **(metadata or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return path


def restore_checkpoint(directory: str, like: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, leaf in flat:
            key = _SEP.join(_path_str(x) for x in p)
            arr = data[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                                 f"expected {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
